"""Three-engine differential harness: every engine ≡ the frozen
cycle-stepped reference model, bit for bit.

``repro.core.sim`` replaced the per-cycle generator loop with an event
scheduler that jumps over idle cycles, compiles slices to native Python
generators, and fast-paths the STA/interp models; batch windows (PR 2)
and steady-state pipeline windows (multi-unit grants + the compiled LSQ
run-tick) stack fast paths on top.  None of that may change a single
architectural number: this suite runs the original cycle-stepped
implementation (``ref_machine_cyclestep.py``, a frozen copy) side by side
with the shipping simulator over every ``bench_irregular`` workload and a
sweep of ``randprog`` programs, and requires *exact* equality of cycles,
committed/poisoned store counts, load counts, sync waits, LSQ high-water,
per-array store traces, and final memory.

Every workload runs the shipping simulator in **every engine mode** —
event-stepped, batch-windowed (``batch_window=True``), pipeline-windowed
(``pipeline_window=True``), and both windows together — and each must
match the frozen reference exactly, so every windowed fast path is held
to the same bit-for-bit bar as the event rewrite was.  The randprog sweep
seeds from the single ``DAE_TEST_SEED`` knob (default: a fixed base, so
CI reruns are reproducible by construction).
"""
import numpy as np
import pytest

import ref_machine_cyclestep as refm
from conftest import dae_test_seed
from repro.bench_irregular import ALL
from repro.core import interp, machine, pipeline, randprog

VARIANTS = (("dae", pipeline.compile_dae),
            ("spec", pipeline.compile_spec),
            ("oracle", pipeline.compile_oracle))

RESULT_FIELDS = ("cycles", "stores_committed", "stores_poisoned",
                 "loads_served", "sync_waits", "lsq_high_water")

# engine modes: (tag, batch_window, pipeline_window)
MODES = (("evt", False, False),
         ("win", True, False),
         ("pipe", False, True),
         ("both", True, True))

# randprog sweep, seeded from the single DAE_TEST_SEED knob: the default
# seed keeps the historical base-0 sweep (stable CI), any other value
# re-rolls the whole sample
_base = dae_test_seed()
RANDPROG_SEEDS = [(0 if _base == 0xDAE else _base) + i for i in range(32)]


def _assert_same_run(tag, agu, cu, memory, decoupled, params=None,
                     width=None):
    mem_ref = {k: v.copy() for k, v in memory.items()}
    ref_cfg = refm.MachineConfig(width=width) if width else None
    r_ref = refm.run_dae(agu, cu, mem_ref, decoupled, params, ref_cfg)
    for mode, windowed, pipelined in MODES:
        mem_new = {k: v.copy() for k, v in memory.items()}
        cfg = machine.MachineConfig(batch_window=windowed,
                                    pipeline_window=pipelined,
                                    **({"width": width} if width else {}))
        r_new = machine.run_dae(agu, cu, mem_new, decoupled, params, cfg)
        for f in RESULT_FIELDS:
            assert getattr(r_ref, f) == getattr(r_new, f), \
                (f"{tag}/{mode}: {f} ref={getattr(r_ref, f)} "
                 f"new={getattr(r_new, f)}")
        assert r_ref.store_trace == r_new.store_trace, \
            f"{tag}/{mode}: store_trace"
        for k in mem_ref:
            assert np.array_equal(mem_ref[k], mem_new[k]), \
                f"{tag}/{mode}: memory {k}"
        # window accounting invariants, per kind
        if not windowed and not pipelined:
            assert r_new.window_cycles == 0 and r_new.window_grants == 0, \
                f"{tag}: slice windows fired with batch_window=False"
        if not pipelined:
            assert (r_new.pipeline_cycles == 0
                    and r_new.pipeline_grants == 0), \
                f"{tag}: pipeline windows fired with pipeline_window=False"
        assert 0 <= r_new.window_cycles, f"{tag}/{mode}: window_cycles < 0"
        assert 0 <= r_new.pipeline_cycles, \
            f"{tag}/{mode}: pipeline_cycles < 0"
        assert (r_new.window_cycles + r_new.pipeline_cycles
                <= r_new.cycles), \
            f"{tag}/{mode}: window accounting exceeds simulated cycles"
        assert 0.0 <= r_new.window_hit_rate <= 1.0, \
            f"{tag}/{mode}: hit rate out of [0, 1]"


@pytest.mark.parametrize("bench", sorted(ALL))
@pytest.mark.parametrize("variant", [v for v, _ in VARIANTS])
def test_bench_bit_identical(bench, variant):
    case = ALL[bench]()
    compile_fn = dict(VARIANTS)[variant]
    comp = compile_fn(case.fn, case.decoupled)
    _assert_same_run(f"{bench}/{variant}", comp.agu, comp.cu, case.memory,
                     case.decoupled, case.params)


@pytest.mark.parametrize("seed", RANDPROG_SEEDS)
def test_randprog_bit_identical(seed):
    g = randprog.generate(seed, n_iter=24)
    for name, compile_fn in VARIANTS[:2]:  # oracle is wrong-by-design
        comp = compile_fn(g.fn, g.decoupled)
        _assert_same_run(f"seed{seed}/{name}", comp.agu, comp.cu,
                         g.memory, g.decoupled)


@pytest.mark.parametrize("bench", sorted(ALL))
def test_sta_fast_path_bit_identical(bench):
    """compile_sta ≡ the interpreted STA model (frozen copy)."""
    case = ALL[bench]()
    mem_ref = {k: v.copy() for k, v in case.memory.items()}
    mem_new = {k: v.copy() for k, v in case.memory.items()}
    r_ref = refm.run_sta(case.fn, mem_ref, case.params)
    r_new = machine.run_sta(case.fn, mem_new, case.params)
    for f in ("cycles", "stores_committed", "loads_served"):
        assert getattr(r_ref, f) == getattr(r_new, f), f"{bench}: {f}"
    assert r_ref.store_trace == r_new.store_trace
    for k in mem_ref:
        assert np.array_equal(mem_ref[k], mem_new[k]), f"{bench}: {k}"


@pytest.mark.parametrize("seed", RANDPROG_SEEDS[:12])
def test_interp_fast_path_bit_identical(seed, monkeypatch):
    """compile_interp ≡ the dict-env interpreter (trace + memory)."""
    from repro.core.sim import compile as simc
    g = randprog.generate(seed, n_iter=24)
    mem_slow = {k: v.copy() for k, v in g.memory.items()}
    mem_fast = {k: v.copy() for k, v in g.memory.items()}
    monkeypatch.setattr(simc, "compile_interp", lambda fn: None)
    t_slow = interp.run(g.fn, mem_slow)
    monkeypatch.undo()
    t_fast = interp.run(g.fn.clone(), mem_fast)
    assert t_slow.stores == t_fast.stores
    assert t_slow.loads == t_fast.loads
    assert t_slow.blocks == t_fast.blocks
    assert t_slow.instr_count == t_fast.instr_count
    for k in mem_slow:
        assert np.array_equal(mem_slow[k], mem_fast[k])


def _float_roundtrip_prog(n=32):
    """Loads feed stores that are re-loaded after wraparound, so any
    skipped float32 rounding at commit leaks into later values."""
    from repro.core.ir import Function
    f = Function("f32rt")
    f.array("A", n)
    e = f.block("entry")
    e.const("zero", 0)
    e.const("one", 1)
    e.const("c3", 3)
    e.const("c6", 6)
    e.const("c13", 13)
    e.const("N", 4 * n)
    h = f.block("header")
    e.br("header")
    h.phi("i", [("entry", "zero"), ("latch", "i_next")])
    h.bin("c", "<", "i", "N")
    h.cbr("c", "body", "exit")
    b = f.block("body")
    # consumer load of slot s runs ~6 iterations after its producer
    # store — long enough for the store to commit, so the load reads
    # memory (the coercion point), not the store-queue forward path
    b.bin("ix", "%", "i", "c13")
    b.load("a", "A", "ix")
    b.bin("t", "*", "a", "c3")
    b.bin("j1", "+", "ix", "c6")
    b.bin("jx", "%", "j1", "c13")
    b.store("A", "jx", "t")
    b.br("latch")
    l = f.block("latch")
    l.bin("i_next", "+", "i", "one")
    l.br("header")
    f.block("exit").ret()
    f.verify()
    rng = np.random.default_rng(0)
    mem = {"A": (rng.integers(1, 9, n).astype(np.float32)
                 * np.float32(0.1))}
    return f, mem


@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_narrow_dtype_bit_identical(dtype):
    """Stores must coerce through the array dtype exactly as a numpy
    assignment would (float32 rounding, int32 truncation) — the list
    mirrors in the LSQ and in compiled slices must not leak the wider
    Python scalar back to later loads."""
    if dtype == np.float32:
        # crafted round-trip program: guaranteed to reload coerced slots
        # (int dtypes skip it: in-range int coercion is value-preserving,
        # and unbounded growth overflows int32 in the reference model too)
        fn, mem = _float_roundtrip_prog()
        for name, compile_fn in VARIANTS[:2]:
            comp = compile_fn(fn, {"A"})
            _assert_same_run(f"{dtype.__name__}/crafted/{name}",
                             comp.agu, comp.cu, mem, {"A"})
    # plus a randprog sweep for incidental coverage
    for seed in (3, 11, 19):
        g = randprog.generate(seed, n_iter=24)
        memory = {k: (v.astype(dtype) if k == "A" else v)
                  for k, v in g.memory.items()}
        for name, compile_fn in VARIANTS[:2]:
            comp = compile_fn(g.fn, g.decoupled)
            _assert_same_run(f"{dtype.__name__}/seed{seed}/{name}",
                             comp.agu, comp.cu, memory, g.decoupled)


def test_interpreted_sliceproc_matches_compiled():
    """The interpreted SliceProc fallback is the spec the compiler must
    match: force it on and compare against the reference model too (both
    event-stepped and windowed — the fallback honours windows as well)."""
    from repro.core.sim import compile as simc
    g = randprog.generate(7, n_iter=24)
    comp = pipeline.compile_spec(g.fn, g.decoupled)
    orig = simc.compile_slice
    try:
        simc.compile_slice = lambda fn: None  # force interpreted generators
        _assert_same_run("interp-sliceproc", comp.agu, comp.cu,
                         g.memory, g.decoupled)
    finally:
        simc.compile_slice = orig


# ---------------------------------------------------------------------------
# Batch-window execution (quiescent-stretch fast path)
# ---------------------------------------------------------------------------


def _quiescent_case(chain=64, n=64):
    from benchmarks.dae_quiescent import build_quiescent
    fn, mem = build_quiescent(n=n, chain=chain)
    return pipeline.compile_spec(fn, {"A"}), mem


@pytest.mark.parametrize("width", [1, 4])
def test_quiescent_windowed_bit_identical(width):
    """The workload shape windows are for: compute-bound CU on a narrow
    slice.  Windowed execution must match the frozen reference exactly
    and must actually fire (otherwise this test guards nothing)."""
    comp, mem = _quiescent_case()
    _assert_same_run(f"quiescent/w{width}", comp.agu, comp.cu, mem, {"A"},
                     width=width)
    cfg = machine.MachineConfig(batch_window=True, width=width)
    mem2 = {k: v.copy() for k, v in mem.items()}
    r = machine.run_dae(comp.agu, comp.cu, mem2, {"A"}, cfg=cfg)
    assert r.window_grants > 0, "no windows granted on a quiescent workload"
    assert r.window_hit_rate > 0.5, \
        f"window hit rate {r.window_hit_rate:.3f} too low for this shape"


def test_quiescent_windowed_interpreted():
    """Window consumption in the interpreted SliceProc fallback (the
    readable spec) is bit-identical too, and also fires."""
    from repro.core.sim import compile as simc
    comp, mem = _quiescent_case(chain=32, n=32)
    orig = simc.compile_slice
    try:
        simc.compile_slice = lambda fn: None
        _assert_same_run("quiescent/interp", comp.agu, comp.cu, mem, {"A"},
                         width=1)
        cfg = machine.MachineConfig(batch_window=True, width=1)
        mem2 = {k: v.copy() for k, v in mem.items()}
        r = machine.run_dae(comp.agu, comp.cu, mem2, {"A"}, cfg=cfg)
        assert r.window_cycles > 0, "interpreted fallback never consumed"
    finally:
        simc.compile_slice = orig


# ---------------------------------------------------------------------------
# Steady-state pipeline windows (multi-unit grants + compiled LSQ tick)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bench", ["spmv", "hist", "sort", "fw"])
def test_pipeline_window_covers_load_dense(bench):
    """The workload shape pipeline windows exist for: the paper's
    load-dense kernels, where the AGU/CU/LSQ set is busy nearly every
    cycle and quiescent windows almost never fire.  Coverage must
    actually materialise (otherwise this suite guards dead code) while
    the three-engine differential assertions above hold bit-for-bit."""
    case = ALL[bench]()
    comp = pipeline.compile_spec(case.fn, case.decoupled)
    mem = {k: v.copy() for k, v in case.memory.items()}
    cfg = machine.MachineConfig(pipeline_window=True)
    r = machine.run_dae(comp.agu, comp.cu, mem, case.decoupled,
                        case.params, cfg)
    assert r.pipeline_grants > 0, f"{bench}: no pipeline windows granted"
    assert r.pipeline_hit_rate > 0.5, \
        (f"{bench}: pipeline coverage {r.pipeline_hit_rate:.3f} too low "
         f"for a load-dense kernel")


def test_pipeline_window_env_knob(monkeypatch):
    """DAE_SIM_PIPELINE=1 flips the config default machine-wide."""
    monkeypatch.setenv("DAE_SIM_PIPELINE", "1")
    assert machine.MachineConfig().pipeline_window
    monkeypatch.setenv("DAE_SIM_PIPELINE", "0")
    assert not machine.MachineConfig().pipeline_window
    monkeypatch.delenv("DAE_SIM_PIPELINE")
    assert not machine.MachineConfig().pipeline_window


def test_quiescent_still_wins_under_pipeline():
    """Pipeline mode subsumes the quiescent slice grant: on the
    compute-bound quiescent shape, slice windows keep firing (and keep
    their coverage) with pipeline_window on."""
    comp, mem = _quiescent_case(chain=32, n=32)
    cfg = machine.MachineConfig(pipeline_window=True, width=1)
    mem2 = {k: v.copy() for k, v in mem.items()}
    r = machine.run_dae(comp.agu, comp.cu, mem2, {"A"}, cfg=cfg)
    assert r.window_grants > 0, "slice windows stopped firing in pipe mode"
    assert r.quiescent_hit_rate > 0.5, \
        "slice windows lost their coverage on the quiescent shape"


def test_event_queue_runnable():
    """``runnable`` is the spec of the steady-state grant condition."""
    from repro.core.sim.events import INF, EventQueue

    class U:
        def __init__(self, wake):
            self.wake = wake

    evq = EventQueue()
    a, b, c = U(3), U(3), U(7)
    for u in (a, b, c):
        evq.register(u)
    assert evq.runnable(3) == [a, b]
    w1, _, w2 = evq.next_two()
    assert w1 == w2 == 3  # the steady-grant shape: >= 2 runnable at w1
    a.wake = INF
    assert evq.runnable(3) == [b]


def test_event_queue_next_two():
    """next_two is the spec of the machine loop's inlined grant scan."""
    from repro.core.sim.events import INF, EventQueue

    class U:
        def __init__(self, wake):
            self.wake = wake

    evq = EventQueue()
    a, b, c = U(5), U(2), U(9)
    for u in (a, b, c):
        evq.register(u)
    w1, u1, w2 = evq.next_two()
    assert (w1, u1, w2) == (2, b, 5)
    b.wake = 5  # tie: second-earliest equals earliest, forbidding a grant
    w1, u1, w2 = evq.next_two()
    assert w1 == 5 and w2 == 5
    for u in (a, b, c):
        u.wake = INF
    w1, u1, w2 = evq.next_two()
    assert w1 is INF and u1 is None
