"""Substrate: optimizer correctness, checkpoint atomicity/async/elastic,
fault policies, data pipeline determinism, end-to-end training convergence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import get, smoke
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.models.model import build_model
from repro.train.checkpoint import CheckpointManager
from repro.train.fault import FaultConfig, FaultMonitor, plan_remesh
from repro.train.train_step import make_train_step


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------


def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)))
    params = {"w": jnp.zeros((8, 8))}

    def loss(p):
        return jnp.mean((p["w"] - target) ** 2)

    return params, loss, target


@pytest.mark.parametrize("make", [
    lambda: optim.adamw(1e-1, weight_decay=0.0),
    lambda: optim.adafactor(2e-1),
])
def test_optimizers_descend(make):
    params, loss, target = _quad_problem()
    init, update = make()
    state = init(params)
    l0 = float(loss(params))
    for _ in range(60):
        grads = jax.grad(loss)(params)
        params, state = update(grads, state, params)
    assert float(loss(params)) < 0.1 * l0


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros((4,))}
    init, update = optim.adamw(1e-2, clip_norm=1.0, weight_decay=0.0)
    state = init(params)
    huge = {"w": jnp.full((4,), 1e9)}
    new_params, _ = update(huge, state, params)
    assert np.all(np.abs(np.asarray(new_params["w"])) < 1.0)


def test_compression_error_feedback_unbiased():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(64,))
                          .astype(np.float32))}
    res = optim.init_residual(g)
    acc = jnp.zeros((64,))
    for _ in range(30):
        cg, res = optim.error_feedback_compress(g, res)
        acc = acc + cg["w"]
    # mean compressed gradient converges to the true gradient
    np.testing.assert_allclose(np.asarray(acc / 30), np.asarray(g["w"]),
                               atol=1e-2)


# ---------------------------------------------------------------------------
# training loop end-to-end (tiny model learns the synthetic bigram)
# ---------------------------------------------------------------------------


def test_training_convergence():
    cfg = smoke(get("phi4_mini_3_8b"))
    model = build_model(cfg)
    init_state, train_step, opt_name = make_train_step(
        model, peak_lr=3e-3, warmup=10)
    assert opt_name == "adamw"
    state = init_state(jax.random.PRNGKey(0))
    data = SyntheticLM(DataConfig(vocab=cfg.vocab, seq_len=32,
                                  global_batch=8))
    step = jax.jit(train_step)
    losses = []
    for i in range(60):
        state, m = step(state, {k: jnp.asarray(v)
                                for k, v in data.batch_at(i).items()})
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::10]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"w": jnp.arange(8.0), "step": jnp.asarray(3)}
    mgr.save(3, state)
    mgr.save(7, state)
    mgr.save(11, state)
    assert mgr.latest_step() == 11
    assert mgr.all_steps() == [7, 11]  # gc kept 2
    back = mgr.restore()
    np.testing.assert_array_equal(back["w"], np.arange(8.0))
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((1024,))}
    mgr.save_async(1, state)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_elastic_reshard(tmp_path):
    """Save replicated, restore with a shard_fn (the elastic-restart path)."""
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.arange(16.0)}
    mgr.save(0, state)
    calls = []

    def shard_fn(tree):
        calls.append(True)
        return jax.tree.map(jnp.asarray, tree)

    back = mgr.restore(shard_fn=shard_fn)
    assert calls and back["w"].shape == (16,)


# ---------------------------------------------------------------------------
# fault policies
# ---------------------------------------------------------------------------


def test_fault_dead_host_detection():
    t = [0.0]
    mon = FaultMonitor(["a", "b"], FaultConfig(dead_after=10),
                       clock=lambda: t[0])
    t[0] = 5.0
    mon.heartbeat("a")
    t[0] = 12.0
    action, hosts = mon.decide()
    assert action == "RESTART_ELASTIC" and hosts == ["b"]


def test_fault_straggler_detection():
    mon = FaultMonitor(["a", "b", "c", "d"],
                       FaultConfig(straggler_factor=1.5, patience=2))
    for _ in range(4):
        for h in "abcd":
            mon.heartbeat(h)
            mon.report_step(h, 10.0 if h != "d" else 30.0)
        action, hosts = mon.decide()
    assert action == "REDISPATCH" and hosts == ["d"]


def test_plan_remesh_shrinks_data_axis_first():
    assert plan_remesh(512) == (2, 16, 16)
    assert plan_remesh(511) == (31, 16)      # lost a node: biggest fillable
    assert plan_remesh(240) == (15, 16)      # keep model axis whole
    assert plan_remesh(16) == (1, 16)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    d1, d2 = SyntheticLM(cfg), SyntheticLM(cfg)
    np.testing.assert_array_equal(d1.batch_at(42)["tokens"],
                                  d2.batch_at(42)["tokens"])
    assert not np.array_equal(d1.batch_at(1)["tokens"],
                              d1.batch_at(2)["tokens"])


def test_data_host_sharding_disjoint():
    a = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=8,
                               n_hosts=2, host_id=0))
    b = SyntheticLM(DataConfig(vocab=100, seq_len=8, global_batch=8,
                               n_hosts=2, host_id=1))
    assert a.per_host == 4
    assert not np.array_equal(a.batch_at(0)["tokens"],
                              b.batch_at(0)["tokens"])


def test_prefetcher():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    pf = Prefetcher(iter(SyntheticLM(cfg)), depth=2)
    b0 = next(pf)
    b1 = next(pf)
    assert b0["tokens"].shape == (2, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    pf.close()
