"""Unit tests for the dry-run machinery that don't need 512 devices:
shape/skip logic, input specs, sharding rules, roofline math."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ASSIGNED, get, param_count


def test_skip_logic_matches_design():
    from repro.launch.dryrun import shape_skip_reason
    runnable = {a: [] for a in ASSIGNED}
    for a in ASSIGNED:
        cfg = get(a)
        for s in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape_skip_reason(cfg, s) is None:
                runnable[a].append(s)
    # ssm + hybrid keep long_500k; everyone else drops exactly that one
    assert "long_500k" in runnable["rwkv6_7b"]
    assert "long_500k" in runnable["jamba_1_5_large_398b"]
    for a in ASSIGNED:
        if a in ("rwkv6_7b", "jamba_1_5_large_398b"):
            assert len(runnable[a]) == 4
        else:
            assert len(runnable[a]) == 3
    # 32 runnable cells + 8 documented skips = the 40-cell matrix
    assert sum(len(v) for v in runnable.values()) == 32


@pytest.mark.parametrize("arch", ASSIGNED)
def test_input_specs_complete(arch):
    from repro.launch.dryrun import SHAPES, input_specs, shape_skip_reason
    cfg = get(arch)
    for shape in SHAPES:
        if shape_skip_reason(cfg, shape):
            continue
        ins = input_specs(cfg, shape)
        assert "tokens" in ins
        assert ins["tokens"].dtype == jnp.int32
        if cfg.family == "encdec":
            assert "frames" in ins        # stubbed modality frontend
        if cfg.family == "vlm":
            assert "patches" in ins


def test_param_counts_sane():
    """Sanity-pin the assigned configs against their public names."""
    total, active = param_count(get("kimi_k2_1t_a32b"))
    assert 0.9e12 < total < 1.2e12          # ~1T
    assert 25e9 < active < 40e9             # a32b
    total, _ = param_count(get("grok_1_314b"))
    assert 250e9 < total < 360e9
    total, _ = param_count(get("granite_34b"))
    assert 30e9 < total < 50e9
    total, _ = param_count(get("phi4_mini_3_8b"))
    assert 3e9 < total < 5.5e9
    total, _ = param_count(get("rwkv6_7b"))
    assert 5e9 < total < 9e9
    total, _ = param_count(get("jamba_1_5_large_398b"))
    assert 330e9 < total < 450e9


def test_param_spec_rules():
    if jax.device_count() < 4:
        pytest.skip("needs XLA_FLAGS=--xla_force_host_platform_device_count")
    from repro.launch.mesh import param_spec
    from repro.launch.mesh import auto_axis_types
    mesh = jax.make_mesh((2, 2), ("data", "model"), **auto_axis_types(2))
    cfg = get("kimi_k2_1t_a32b")
    # experts: EP over model when divisible
    s = param_spec("groups/s1_moe/w_gate", (384, 7168, 2048), cfg, mesh,
                   fsdp=True)
    assert s[0] == "model" and s[1] == "data"
    # attention: column-parallel
    s = param_spec("groups/s0_attn/wq", (7168, 7168), cfg, mesh, fsdp=True)
    assert s[1] == "model"
    # contraction-side mats: row-parallel
    s = param_spec("groups/s0_attn/wo", (7168, 7168), cfg, mesh, fsdp=True)
    assert s[0] == "model"
    # vectors replicate
    assert param_spec("groups/s0_attn/ln", (7168,), cfg, mesh, True) == P(None)
    # embedding: vocab on model
    s = param_spec("embed", (163840, 7168), cfg, mesh, fsdp=True)
    assert s[0] == "model"


def test_roofline_analysis_math():
    from repro.launch.roofline import analyze
    rec = {
        "arch": "x", "shape": "train_4k", "n_devices": 256,
        "flops": 197e12,            # exactly 1 s of compute per chip
        "bytes_accessed": 819e9,    # exactly 1 s of HBM per chip
        "collective_bytes": {"total": 100e9},  # 2 s of ICI
        "params_active": 1e9,
    }
    r = analyze(rec)
    assert abs(r.compute_s - 1.0) < 1e-6
    assert abs(r.memory_s - 1.0) < 1e-6
    assert abs(r.collective_s - 2.0) < 1e-6
    assert r.dominant == "collective"
    assert r.step_time_s == r.collective_s
    # MODEL_FLOPS = 6 * 1e9 * (256*4096) tokens
    assert abs(r.model_flops - 6e9 * 256 * 4096) / r.model_flops < 1e-9


def test_collective_parser_handles_tuples():
    from repro.launch.hlo_cost import analyze_hlo
    hlo = """
ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %ar = f32[8,8]{1,0} all-reduce(%p0), replica_groups={}
  ROOT %r = f32[8,8]{1,0} add(%ar, %ar)
}
"""
    out = analyze_hlo(hlo)
    assert out["all-reduce"] == 8 * 8 * 4
