"""Structural tests for §3.2 decoupling, §5.1 hoisting, §5.2/5.3 poisoning."""

from repro.core import lod, pipeline
from repro.core.ir import Function


def fig1b(N=64):
    """for i: a=A[i]; if a>0: j=idx[i]; A[j] += 1   (the paper's Fig. 1b)."""
    f = Function("hist")
    f.array("A", N); f.array("idx", N)
    e = f.block("entry"); e.const("zero", 0); e.const("one", 1)
    e.const("N", N); e.br("header")
    h = f.block("header"); h.phi("i", [("entry", "zero"), ("latch", "i_next")])
    h.bin("c", "<", "i", "N"); h.cbr("c", "body", "exit")
    b = f.block("body"); b.load("a", "A", "i"); b.bin("p", ">", "a", "zero")
    b.cbr("p", "then", "latch")
    t = f.block("then"); t.load("j", "idx", "i"); t.load("x", "A", "j")
    t.bin("x1", "+", "x", "one"); t.store("A", "j", "x1"); t.br("latch")
    l = f.block("latch"); l.bin("i_next", "+", "i", "one"); l.br("header")
    f.block("exit").ret()
    f.verify()
    return f


def test_lod_analysis_fig1b():
    f = fig1b()
    info = lod.analyze(f, {"A"})
    # the branch block 'body' is the (only) LoD source
    assert info.tainted_branches == {"body"}
    assert "a" in info.tainted and "p" in info.tainted
    # the store (and the A[j] load) chain to head 'body'
    store_mids = [m for m, b in info.request_block.items() if b == "then"]
    assert store_mids
    for m in store_mids:
        assert info.chain_heads[m] == {"body"}
        ok, why = lod.speculable(info, m)
        assert ok, why
    assert not info.data_lod


def test_data_lod_refused():
    """if (A[i]) A[i++] = 1 — φ-carried data LoD must not be speculated."""
    f = Function("dyn")
    f.array("A", 16)
    e = f.block("entry"); e.const("zero", 0); e.const("one", 1)
    e.const("N", 16); e.br("header")
    h = f.block("header")
    h.phi("i", [("entry", "zero"), ("latch", "i_next")])
    h.phi("w", [("entry", "zero"), ("latch", "w_next")])
    h.bin("c", "<", "i", "N"); h.cbr("c", "body", "exit")
    b = f.block("body"); b.load("a", "A", "i"); b.bin("p", ">", "a", "zero")
    b.cbr("p", "then", "latch")
    t = f.block("then"); t.store("A", "w", "one")
    t.bin("w1", "+", "w", "one"); t.br("latch")
    l = f.block("latch")
    l.select("w_next", "p", "w1", "w")
    l.bin("i_next", "+", "i", "one"); l.br("header")
    f.block("exit").ret()
    f.verify()
    info = lod.analyze(f, {"A"})
    store_mid = [m for m, b in info.request_block.items() if b == "then"][0]
    assert store_mid in info.data_lod  # w is tainted through the select/φ


def test_spec_restores_decoupling():
    """After SPEC, no AGU send_ld should remain synchronous (Fig. 1c)."""
    comp = pipeline.compile_spec(fig1b(), {"A"})
    syncs = [i for blk in comp.agu.blocks.values() for i in blk.body
             if i.op == "send_ld" and i.meta.get("sync")]
    assert not syncs
    # the guarding branch is gone from the AGU
    cbrs = [b for b in comp.agu.blocks.values() if b.term.kind == "cbr"
            and b.name != "header"]
    assert not cbrs


def test_dae_keeps_sync():
    """Without speculation the LoD load stays synchronous (Fig. 1b)."""
    comp = pipeline.compile_dae(fig1b(), {"A"})
    syncs = [i for blk in comp.agu.blocks.values() for i in blk.body
             if i.op == "send_ld" and i.meta.get("sync")]
    assert syncs


def test_poison_counts_fig1b():
    comp = pipeline.compile_spec(fig1b(), {"A"})
    assert comp.poison_stats.poison_blocks == 1
    assert comp.poison_stats.poison_calls == 1


def test_cu_block_structure_preserved():
    """The CU keeps the full original CFG (plus synthetic poison blocks)."""
    f = fig1b()
    comp = pipeline.compile_spec(f, {"A"})
    for name in f.blocks:
        assert name in comp.cu.blocks
    synth = [b for b in comp.cu.blocks.values() if b.synthetic]
    assert len(synth) == comp.poison_stats.poison_blocks


def test_merge_poison_blocks():
    from repro.core.ir import Instr
    from repro.core.poison import merge_poison_blocks
    f = Function("m")
    e = f.block("entry"); e.const("c", 1); e.cbr("c", "p1", "p2")
    for n in ("p1", "p2"):
        b = f.block(n)
        b.synthetic = True
        b.body.append(Instr("poison_st", None, (), "A", {"mid": 7}))
        b.br("out")
    f.block("out").ret()
    merged = merge_poison_blocks(f)
    assert merged == 1
    assert ("p1" in f.blocks) ^ ("p2" in f.blocks)
