"""The 9 paper benchmarks: correctness vs the sequential oracle and the
paper's qualitative performance relations."""
import numpy as np
import pytest

from repro.bench_irregular import ALL
from repro.core import pipeline


@pytest.fixture(scope="module")
def results():
    out = {}
    for name, build in ALL.items():
        case = build()
        out[name] = (case, pipeline.run_all(case.fn, case.decoupled,
                                            case.memory, params=case.params))
    return out


@pytest.mark.parametrize("name", list(ALL))
def test_memory_matches_oracle(results, name):
    case, runs = results[name]
    ref = runs["ref"].memory
    for v in ("sta", "dae", "spec"):
        for k in ref:
            assert np.array_equal(runs[v].memory[k], ref[k]), (name, v, k)


@pytest.mark.parametrize("name", list(ALL))
def test_speculation_is_active(results, name):
    _, runs = results[name]
    comp = runs["spec"].compiled
    assert comp.spec.spec_req_map, f"{name}: nothing was speculated"
    assert not any("hazard" in v for v in comp.spec.fallback.values()), \
        f"{name}: hazard fallback fired: {comp.spec.fallback}"


@pytest.mark.parametrize("name", list(ALL))
def test_spec_beats_dae(results, name):
    """The paper's core claim: speculation recovers the decoupling loss."""
    _, runs = results[name]
    assert runs["spec"].cycles < runs["dae"].cycles


@pytest.mark.parametrize("name", list(ALL))
def test_spec_beats_sta(results, name):
    _, runs = results[name]
    assert runs["spec"].cycles < runs["sta"].cycles


@pytest.mark.parametrize("name", list(ALL))
def test_spec_close_to_oracle(results, name):
    """SPEC within ~30% of the manual-LoD-removal bound (paper: <5% avg,
    worst cases bfs/bc larger due to LSQ pressure)."""
    _, runs = results[name]
    assert runs["spec"].cycles <= 1.35 * runs["oracle"].cycles


def test_bc_uses_two_lsqs(results):
    case, _ = results["bc"]
    assert case.decoupled == {"D", "S"}


def test_misspec_rates_nontrivial(results):
    rates = {n: runs["spec"].result.misspec_rate
             for n, (_, runs) in results.items()}
    assert rates["bfs"] > 0.5     # paper: 95%
    assert rates["hist"] < 0.1    # paper: 2%
    assert 0.2 < rates["sort"] < 0.8  # paper: 49%
