"""The standalone soundness verifier (`repro.verify`) — see docs/verify.md.

Four gates ride here:

* **independence** — the verifier's analysis modules must never import
  ``repro.codegen`` (scanned from source), so the second opinion cannot
  inherit the classifier's bugs;
* **differential** — every workload and a 32-seed randprog sweep must be
  soundness-clean AND agree with the codegen classifier in both
  directions (schedule verdicts, forwarding-chain slots);
* **mutation testing** — every seeded soundness mutant must be caught by
  exactly its expected rule (a survivor is a verifier hole);
* **reason tagging** — ``CodegenRun`` reason strings and
  ``FailureEvent.cause`` lead with registry rule IDs.
"""
import os
import re

import numpy as np
import pytest

import repro.verify as verify
from repro.bench_irregular import ALL
from repro.core import pipeline, randprog
from repro.core.cfg import CFGInfo
from repro.core.ir import Function, Instr
from repro.verify import mutate, rules
from repro.verify.__main__ import differential, main as verify_main

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src", "repro", "verify")


# ---------------------------------------------------------------------------
# rule registry + Diag plumbing
# ---------------------------------------------------------------------------


def test_registry_ids_are_well_formed():
    assert rules.REGISTRY_VERSION == 1
    for rid, precond in rules.RULES.items():
        assert re.fullmatch(r"[CPDVFX]\d{2}-[a-z0-9-]+", rid), rid
        assert precond.strip()
    assert rules.SCHEDULE_RULES < set(rules.RULES)


def test_diag_rejects_unknown_rule():
    with pytest.raises(KeyError):
        rules.Diag("Z99-not-a-rule", "cu", "nope")
    d = rules.Diag("P01-poison-escapes-commit", "cu:b0", "detail text")
    assert str(d) == "P01-poison-escapes-commit @cu:b0: detail text"


def test_tag_round_trip():
    s = rules.tag("V02-epoch-stalled", "vector epoch stalled: RAW")
    assert rules.rule_of(s) == "V02-epoch-stalled"
    assert rules.detail_of(s) == "vector epoch stalled: RAW"
    assert "stalled" in s  # the human text stays a substring
    assert rules.rule_of("plain untagged reason") is None
    assert rules.detail_of("plain untagged reason") == "plain untagged reason"
    assert rules.rule_of(None) is None
    with pytest.raises(KeyError):
        rules.tag("Z99-nope", "x")


def test_soundness_filter_excludes_schedule_rules():
    d01 = rules.Diag("D01-agu-value-dependent", "agu", "legal but coupled")
    p02 = rules.Diag("P02-request-unresolved", "cu:b", "wedged")
    assert verify.soundness([d01, p02]) == [p02]


# ---------------------------------------------------------------------------
# independence: the analysis modules never import codegen
# ---------------------------------------------------------------------------


def test_import_boundary_pins_independence():
    analysis_modules = ["rules.py", "poisonflow.py", "decoupling.py",
                        "mutate.py", "__init__.py"]
    offenders = []
    for name in analysis_modules:
        with open(os.path.join(SRC, name)) as fh:
            for ln, line in enumerate(fh, 1):
                code = line.split("#", 1)[0]
                if re.match(r"\s*(import|from)\s+[\w.]*\bcodegen\b", code):
                    offenders.append(f"{name}:{ln}: {line.strip()}")
    assert not offenders, (
        "verifier analysis modules import codegen (independence broken):\n"
        + "\n".join(offenders))
    # ... and the CLI driver is allowed to (the differential needs it)
    with open(os.path.join(SRC, "__main__.py")) as fh:
        assert "codegen" in fh.read()


# ---------------------------------------------------------------------------
# differential: workloads + randprog sweep, both directions
# ---------------------------------------------------------------------------


def _compiled(name):
    case = ALL[name]()
    return pipeline.compile_spec(case.fn, case.decoupled), case.memory


@pytest.mark.parametrize("name", sorted(ALL))
def test_workload_verifies_clean_and_matches_classifier(name):
    comp, memory = _compiled(name)
    diags, splits = differential(comp, memory)
    assert not verify.soundness(diags), [str(d) for d in diags]
    assert not splits, [str(d) for d in splits]


def test_randprog_sweep_differential():
    for kw in ({}, {"assoc_chains": True}):
        for seed in range(32):
            g = randprog.generate(seed, **kw)
            comp = pipeline.compile_spec(g.fn, g.decoupled)
            diags, splits = differential(comp, g.memory)
            assert not verify.soundness(diags), (seed, kw, diags)
            assert not splits, (seed, kw, [str(d) for d in splits])


def test_cli_runs_clean():
    assert verify_main(["--all", "--randprog", "8", "--negative", "4"]) == 0


# ---------------------------------------------------------------------------
# mutation testing: the verifier has teeth
# ---------------------------------------------------------------------------


def test_every_mutant_is_caught_by_its_expected_rule():
    caught_kinds = set()
    survivors = []
    for name in sorted(ALL):
        comp, memory = _compiled(name)
        for kind, rule, ok in mutate.check_mutants(comp, memory):
            caught_kinds.add(kind) if ok else survivors.append((name, kind,
                                                                rule))
    assert not survivors, f"mutants the verifier missed: {survivors}"
    # the acceptance bar: at least 8 distinct soundness breaks proven
    assert len(caught_kinds) >= 8, sorted(caught_kinds)


def _steered_pair():
    """A hand-built AGU/CU pair with a pred_reg-steered END poison.

    The benchmark compiles never produce steering (their spec heads
    dominate every poison edge), so P03's material is built by hand: the
    store request is hoisted (sent unconditionally in ``body``), the CU
    commits on the ``spec`` arm and fires a flag-guarded latch poison on
    the ``skip`` arm — the Fig. 4 steering discipline in miniature.
    """
    agu = Function("steer.agu")
    agu.array("A", 8)
    e = agu.block("entry")
    e.const("zero", 0)
    e.const("one", 1)
    e.const("N", 8)
    e.br("header")
    h = agu.block("header")
    h.phi("i", [("entry", "zero"), ("latch", "i_next")])
    h.bin("cond", "<", "i", "N")
    h.cbr("cond", "body", "exit")
    b = agu.block("body")
    b.body.append(Instr("send_ld", None, ("i",), "A",
                        {"mid": 0, "sync": False}))
    b.body.append(Instr("send_st", None, ("i",), "A", {"mid": 1}))
    b.br("latch")
    l = agu.block("latch")
    l.bin("i_next", "+", "i", "one")
    l.br("header")
    agu.block("exit").ret()
    agu.verify()

    cu = Function("steer.cu")
    cu.array("A", 8)
    e = cu.block("entry")
    e.const("zero", 0)
    e.const("one", 1)
    e.const("N", 8)
    e.const("c", 3)
    e.br("header")
    h = cu.block("header")
    h.phi("i", [("entry", "zero"), ("latch", "i_next")])
    h.body.append(Instr("setreg", None, ("steer.x",), None, {"imm": 0}))
    h.bin("cond", "<", "i", "N")
    h.cbr("cond", "body", "exit")
    b = cu.block("body")
    b.body.append(Instr("consume_ld", "av", (), "A", {"mid": 0}))
    b.bin("p", "<", "av", "c")
    b.cbr("p", "spec", "skip")
    s = cu.block("spec")
    s.bin("v", "+", "av", "c")
    s.body.append(Instr("produce_st", None, ("v",), "A", {"mid": 1}))
    s.br("join")
    k = cu.block("skip")
    k.body.append(Instr("setreg", None, ("steer.x",), None, {"imm": 1}))
    k.br("join")
    j = cu.block("join")
    j.br("latch")
    l = cu.block("latch")
    l.body.append(Instr("poison_st", None, (), "A",
                        {"mid": 1, "poison": True, "pred_reg": "steer.x"}))
    l.bin("i_next", "+", "i", "one")
    l.br("header")
    cu.block("exit").ret()
    cu.verify()

    class Pair:
        pass

    pair = Pair()
    pair.agu, pair.cu = agu, cu
    return pair


def test_steered_pair_is_clean():
    assert verify.verify_compiled(_steered_pair()) == []


def test_steer_mutants_caught_by_p03():
    results = dict((kind, (rule, ok)) for kind, rule, ok
                   in mutate.check_mutants(_steered_pair()))
    for kind in ("drop-steer-reset", "drop-steer-set"):
        rule, ok = results[kind]
        assert rule == "P03-steer-discipline"
        assert ok, f"{kind} survived"


def test_mutants_carry_expected_rule_not_just_any():
    # a P02 mutant must be reported as P02, not merely *something*
    comp, memory = _compiled("hist")
    for kind, mut, rule in mutate.mutants(comp):
        diags = verify.verify_compiled(mut, memory)
        assert any(d.rule == rule for d in diags), (
            kind, rule, [str(d) for d in diags])


# ---------------------------------------------------------------------------
# negative corpus + the irreducible-CFG error path
# ---------------------------------------------------------------------------


def test_negative_randprog_corpus():
    import random
    for seed in range(8):
        g = randprog.generate(seed, negative=True)
        assert g.expect_rule
        if g.mutate:
            comp = pipeline.compile_spec(g.fn, g.decoupled)
            m = mutate._clone(comp)
            assert mutate._APPLY[g.mutate](m, random.Random(seed))
            diags = verify.verify_compiled(m, g.memory)
        else:
            diags = verify.verify_function(g.fn)
        assert any(d.rule == g.expect_rule for d in diags), (
            seed, g.expect_rule, [str(d) for d in diags])


def test_irreducible_cfg_error_path_is_pinned():
    g = randprog.generate(0, negative=True)  # even seed: irreducible
    # the core CFG layer refuses with the canonical message ...
    with pytest.raises(ValueError, match="irreducible CFG: retreating edge"):
        CFGInfo(g.fn)
    # ... the verifier maps it to C02 ...
    [d] = verify.verify_function(g.fn)
    assert d.rule == "C02-irreducible-cfg"
    assert "node splitting" in d.detail
    # ... and the compile pipeline (codegen side) refuses it too
    with pytest.raises(ValueError, match="irreducible"):
        pipeline.compile_spec(g.fn, g.decoupled)


# ---------------------------------------------------------------------------
# reason strings carry rule IDs
# ---------------------------------------------------------------------------


def test_reason_strings_lead_with_rule_ids():
    from repro import codegen

    # D01: a value-dependent AGU's stream refusal
    g = next(randprog.generate(s) for s in (18,))  # known value-dep seed
    comp = pipeline.compile_spec(g.fn, g.decoupled)
    info = codegen.analyze(comp)
    if info.stream_reason is not None:
        assert rules.rule_of(info.stream_reason) in (
            "D01-agu-value-dependent", "V05-op-not-lowerable")

    # V01: the uniformity classifier's refusal (human text intact)
    from repro.core.ir import LoopNest
    f = Function("steered")
    f.array("A", 8)
    nest = LoopNest(f)
    b = nest.enter("i", nest.const(8, "N"))
    b.body.append(Instr("consume_ld", "av", (), "A", {}))
    b.body.append(Instr("poison_st", None, (), "A",
                        {"poison": True, "pred_reg": "steer.x"}))
    b.br(nest.latch)
    nest.finish()
    loops, why = codegen.analysis.uniform_loops(f)
    assert loops is None
    assert rules.rule_of(why) == "V01-cu-not-uniform"
    assert "steered poison" in why

    # F01: a forced forwarding refusal on a real run
    case = ALL["hist"]()
    comp = pipeline.compile_spec(case.fn, case.decoupled)
    mem = {k: v.copy() for k, v in case.memory.items()}
    r = codegen.run(comp, mem, case.params, cu_mode="vector", forward=False)
    assert rules.rule_of(r.forward_reason) == "F01-forward-refused"
    assert rules.detail_of(r.forward_reason) == \
        "forwarding disabled (forward=False)"


def test_failure_event_rule_property():
    from repro.resilience.ladder import FailureEvent

    ev = FailureEvent(site="", rung="vector",
                      cause=rules.tag("V02-epoch-stalled", "stalled"),
                      retries=0, outcome="descend")
    assert ev.rule == "V02-epoch-stalled"
    raw = FailureEvent(site="x", rung="vector", cause="untagged fault",
                       retries=0, outcome="retry")
    assert raw.rule is None


def test_vector_reason_is_tagged_on_fallback():
    from repro import codegen

    # the steered CU refuses vector mode; run through codegen.run via a
    # pair that the ladder must descend on is heavyweight, so check the
    # raise site directly instead
    from repro.codegen.vector import run_vector
    from repro.codegen import CodegenError

    pair = _steered_pair()
    mem = {"A": np.arange(8, dtype=np.int64)}
    streams = None
    with pytest.raises(CodegenError) as ei:
        run_vector(pair, mem, {}, streams, codegen.analyze(pair), "numpy")
    assert rules.rule_of(str(ei.value)) == "V01-cu-not-uniform"
    assert "not iteration-uniform" in str(ei.value)
