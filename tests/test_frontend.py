"""The composable frontend (repro.frontend) and its persistent compile
cache.

Pins the subsystem's three contracts:

* **Lowering** — frontend recordings replay through ``ir.LoopNest`` to
  IR that is *byte-identical* (``Function.dump()`` equality) to the
  hand-rolled builders (hist/spmv re-expressions diffed against
  ``bench_irregular``; sort — now frontend-authored — against the frozen
  pre-port golden text), plus a golden text for the structures no
  hand-rolled bench had: sequential sibling loops, else-arms, join
  blocks.
* **Cache** — cold → warm → invalidate round-trips on a tmp root; the
  warm path must skip re-analysis/re-tracing *provably* (analysis and
  emission entry points are monkeypatched to raise); a corrupted or
  drifted payload is discarded with ``FailureEvent(frontend.cache_stale)``
  and recompiled, never silently reused.
* **Equivalence** — a 16-seed random frontend program sweep and the two
  frontend-authored workload families hold bit-identical to
  ``interp.run`` across the numpy, numpy-vector, and jax codegen legs,
  and across the sim engines.
"""
import os
import pickle

import numpy as np
import pytest

from conftest import dae_test_seed
from repro import codegen
from repro.bench_irregular import ALL
from repro.core import interp, machine, pipeline
from repro.frontend import CompileCache, FrontendError, dae
from repro.frontend import cache as cache_mod

SEEDS = [dae_test_seed() + k for k in range(16)]


# ---------------------------------------------------------------------------
# lowering: byte-identity vs the hand-rolled builders
# ---------------------------------------------------------------------------


def _frontend_hist(n=256, n_bins=32, max_count=1 << 30):
    p = dae("hist", arrays={"H": n_bins, "bins": n, "w": n})
    with p.range_loop("i", p.const(n, "N")):
        p.load("b", "bins", "i")
        p.load("hv", "H", "b")
        p.bin("p", "<", "hv", p.const(max_count, "MAX"))
        with p.cond("p", then="then"):
            p.load("wv", "w", "i")
            p.bin("h1", "+", "hv", "wv")
            p.store("H", "b", "h1")
    return p


def _frontend_spmv(n, nnz):
    p = dae("spmv", arrays={"V": 2 * n, "row": nnz, "col": nnz, "val": nnz})
    n_name = p.const(n, "n")
    with p.range_loop("i", p.const(nnz, "NNZ")):
        p.load("cl", "col", "i")
        p.load("xv", "V", "cl")
        p.bin("p", "!=", "xv", "zero")
        with p.cond("p", then="then"):
            p.load("rw", "row", "i")
            p.bin("yi", "+", "rw", n_name)
            p.load("yv", "V", "yi")
            p.load("vv", "val", "i")
            p.bin("prod", "*", "vv", "xv")
            p.bin("acc", "+", "yv", "prod")
            p.store("V", "yi", "acc")
    return p


def test_hist_byte_identical():
    assert _frontend_hist().build().dump() == ALL["hist"]().fn.dump()


def test_spmv_byte_identical():
    case = ALL["spmv"]()
    nnz = case.fn.arrays["row"]
    assert _frontend_spmv(20, nnz).build().dump() == case.fn.dump()


# frozen dump of the hand-rolled sort builder as it stood before the
# frontend port (PR 9) — the port must not move a byte
SORT_GOLDEN = """func sort() arrays={a[8], lo[4], hi[4], dir[4]}
entry:
  zero = const [0]
  one = const [1]
  P = const [4]
  br header
header:
  t = phi [('entry', 'zero'), ('latch', 't_next')]
  c = bin ['<', 't', 'P']
  cbr c ? body : exit
body:
  il = load @lo ['t']
  ih = load @hi ['t']
  x = load @a ['il']
  y = load @a ['ih']
  dd = load @dir ['t']
  gt = bin ['>', 'x', 'y']
  p = bin ['==', 'gt', 'dd']
  cbr p ? swap : latch
swap:
  store @a ['il', 'y']
  store @a ['ih', 'x']
  br latch
latch:
  t_next = bin ['+', 't', 'one']
  br header
exit:
  ret"""


def test_sort_port_matches_handrolled_golden():
    p = dae("sort", arrays={"a": 8, "lo": 4, "hi": 4, "dir": 4})
    with p.range_loop("t", p.const(4, "P")):
        p.load("il", "lo", "t")
        p.load("ih", "hi", "t")
        p.load("x", "a", "il")
        p.load("y", "a", "ih")
        p.load("dd", "dir", "t")
        p.bin("gt", ">", "x", "y")
        p.bin("p", "==", "gt", "dd")
        with p.cond("p", then="swap"):
            p.store("a", "il", "y")
            p.store("a", "ih", "x")
    assert p.build().dump() == SORT_GOLDEN


GOLDEN = """func g() arrays={A[8], B[8]}
entry:
  zero = const [0]
  one = const [1]
  N = const [8]
  br header
header:
  i = phi [('entry', 'zero'), ('latch', 'i_next')]
  c = bin ['<', 'i', 'N']
  cbr c ? body : j_header
body:
  av = load @A ['i']
  p = bin ['>', 'av', 'zero']
  cbr p ? pos : neg
pos:
  a_old0 = load @A ['i']
  a_new0 = bin ['+', 'a_old0', 'one']
  store @A ['i', 'a_new0']
  br pos_join
neg:
  store @A ['i', 'zero']
  br pos_join
pos_join:
  bv = load @B ['i']
  store @B ['i', 'av']
  br latch
latch:
  i_next = bin ['+', 'i', 'one']
  br header
j_header:
  j = phi [('header', 'zero'), ('j_latch', 'j_next')]
  j_c = bin ['<', 'j', 'N']
  cbr j_c ? j_body : exit
j_body:
  b2 = load @B ['j']
  store @A ['j', 'b2']
  br j_latch
j_latch:
  j_next = bin ['+', 'j', 'one']
  br j_header
exit:
  ret"""


def test_golden_lowering_sibling_loops_else_join():
    """One recording exercising everything LoopNest never saw before:
    an else-arm, a join block (cond not last), and sequential siblings."""
    p = dae("g", arrays={"A": 8, "B": 8})
    with p.range_loop("i", p.const(8, "N")):
        p.load("av", "A", "i")
        p.bin("p", ">", "av", "zero")
        c = p.cond("p", then="pos")
        with c:
            p.update("A", "i", "one")
        with c.orelse("neg"):
            p.store("A", "i", "zero")
        p.load("bv", "B", "i")
        p.store("B", "i", "av")
    with p.range_loop("j", p.const(8, "N2")):
        p.load("b2", "B", "j")
        p.store("A", "j", "b2")
    assert p.build().dump() == GOLDEN


def test_misuse_raises():
    p = dae("m", arrays={"A": 4})
    with pytest.raises(FrontendError):
        p.const(5, "zero")  # collides with the pooled loop constant
    c = p.cond("x")
    with c:
        p.store("A", "zero", "zero")
    p.load("q", "A", "zero")  # a statement between cond and orelse
    with pytest.raises(FrontendError):
        with c.orelse():
            pass
    q = dae("m2", arrays={"A": 4})
    q.build()
    with pytest.raises(FrontendError):
        q.load("v", "A", "zero")  # recording after lowering


# ---------------------------------------------------------------------------
# cache: cold -> warm -> invalidate, stale guard, no re-analysis on warm
# ---------------------------------------------------------------------------


def _join_prog():
    p = dae("jn", arrays={"HT": 16, "G": 8, "rkey": 12, "rval": 12,
                          "skey": 12, "sval": 12, "sgrp": 12})
    with p.range_loop("i", p.const(12, "NR")):
        p.load("k", "rkey", "i")
        p.load("rv", "rval", "i")
        p.update("HT", "k", "rv")
    with p.range_loop("j", p.const(12, "NS")):
        p.load("k2", "skey", "j")
        p.load("hv", "HT", "k2")
        p.bin("q", "!=", "hv", "zero")
        with p.cond("q", then="hit"):
            p.load("sv", "sval", "j")
            p.bin("w", "*", "hv", "sv")
            p.load("gi", "sgrp", "j")
            p.update("G", "gi", "w")
    return p


def _join_mem(seed=0):
    rng = np.random.default_rng(seed)
    return {"HT": np.zeros(16, dtype=np.int64),
            "G": np.zeros(8, dtype=np.int64),
            "rkey": rng.integers(0, 16, 12).astype(np.int64),
            "rval": rng.integers(1, 5, 12).astype(np.int64),
            "skey": rng.integers(0, 16, 12).astype(np.int64),
            "sval": rng.integers(1, 5, 12).astype(np.int64),
            "sgrp": rng.integers(0, 8, 12).astype(np.int64)}


def test_cache_round_trip(tmp_path):
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    c1 = _join_prog().compile(dec, cache=cc)
    assert c1.cache_stats["outcome"] == "cold"
    c2 = _join_prog().compile(dec, cache=cc)
    assert c2.cache_stats["outcome"] == "warm"
    assert cc.invalidate(_join_prog(), dec)
    c3 = _join_prog().compile(dec, cache=cc)
    assert c3.cache_stats["outcome"] == "cold"
    assert (cc.hits, cc.misses, cc.stale, cc.invalidated) == (1, 2, 0, 1)
    # a different decoupled set or mode is a different key
    assert _join_prog().compile({"HT"}, cache=cc).cache_stats["outcome"] \
        == "cold"
    assert _join_prog().compile(dec, mode="dae",
                                cache=cc).cache_stats["outcome"] == "cold"


def test_cache_warm_skips_analysis_and_runs_bitexact(tmp_path, monkeypatch):
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc)

    # warm path: classification, uniformity analysis and source emission
    # must never re-run — the payload carries their results
    def boom(*a, **k):
        raise AssertionError("warm cache path re-analyzed/re-traced")
    monkeypatch.setattr(codegen, "_analyze_slices", boom)
    monkeypatch.setattr(codegen.emit, "emit_source", boom)
    monkeypatch.setattr(codegen.analysis, "analyze", boom)
    monkeypatch.setattr(codegen.analysis, "uniform_loops", boom)

    warm = _join_prog().compile(dec, cache=cc)
    assert warm.cache_stats["outcome"] == "warm"

    ref = _join_mem()
    interp.run(_join_prog().build(), ref)
    for cu_mode in ("state-machine", "vector"):
        mem = _join_mem()
        r = warm.run_generated(mem, target="numpy", cu_mode=cu_mode)
        assert r.target_used == "numpy" and r.cu_mode == cu_mode
        assert r.cache["outcome"] == "warm" and r.cache["hits"] == 1
        for k in ref:
            assert np.array_equal(mem[k], ref[k]), (cu_mode, k)
    # the sim path runs the cached slices too
    mem = _join_mem()
    machine.run_dae(warm.agu, warm.cu, mem, dec)
    for k in ref:
        assert np.array_equal(mem[k], ref[k]), ("sim", k)


def test_cache_corrupted_payload_is_stale_not_reused(tmp_path):
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc)
    key = cc.key(_join_prog().signature(), dec, "spec")
    with open(cc._path(key), "wb") as fh:
        fh.write(b"not a pickle")
    c = _join_prog().compile(dec, cache=cc)
    assert c.cache_stats["outcome"] == "stale"
    assert cc.stale == 1
    evs = c.cache_stats["events"]
    assert evs and all(e.site == "frontend.cache_stale" for e in evs)
    # the bad entry was discarded and rewritten: next compile is warm
    assert _join_prog().compile(dec, cache=cc).cache_stats["outcome"] \
        == "warm"


def test_cache_ir_drift_is_stale_not_reused(tmp_path):
    """Key collision / stale payload: the stored entry round-trips the
    pickle but its lowered IR differs from the re-lowered program —
    must be discarded via the dump guard, not silently reused."""
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc)
    key = cc.key(_join_prog().signature(), dec, "spec")
    with open(cc._path(key), "rb") as fh:
        payload = pickle.load(fh)
    payload["dump"] = payload["dump"] + "\n; drifted"
    with open(cc._path(key), "wb") as fh:
        pickle.dump(payload, fh)
    c = _join_prog().compile(dec, cache=cc)
    assert c.cache_stats["outcome"] == "stale"
    assert "differs" in c.cache_stats["events"][-1].cause
    ref = _join_mem()
    interp.run(_join_prog().build(), ref)
    mem = _join_mem()
    c.run_generated(mem, target="numpy")
    for k in ref:
        assert np.array_equal(mem[k], ref[k])


def test_cache_schema_stamp_invalidates(tmp_path, monkeypatch):
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc)
    monkeypatch.setattr(cache_mod, "SCHEMA", cache_mod.SCHEMA + 1)
    # new schema -> new key -> the old entry simply never matches
    assert _join_prog().compile(dec, cache=cc).cache_stats["outcome"] \
        == "cold"


def test_resolve_cache_env(tmp_path, monkeypatch):
    monkeypatch.delenv("DAE_CACHE_DIR", raising=False)
    assert cache_mod.resolve_cache(None) is None
    assert cache_mod.resolve_cache(False) is None
    monkeypatch.setenv("DAE_CACHE_DIR", str(tmp_path))
    cc = cache_mod.resolve_cache(None)
    assert isinstance(cc, CompileCache)
    assert cache_mod.resolve_cache(None) is cc  # per-root singleton
    comp = _join_prog().compile({"HT", "G"})
    assert comp.cache_stats["outcome"] == "cold"
    assert os.listdir(tmp_path)


# ---------------------------------------------------------------------------
# 16-seed random frontend programs, all three codegen legs + sim engines
# ---------------------------------------------------------------------------


def _rand_program(seed):
    """A seeded random *frontend* recording: 1-2 sequential top-level
    loops, random op chains, optional guarded updates (control LoD),
    optional else-arms — every structure the API offers."""
    rng = np.random.default_rng(seed)
    n, m = 16, 20
    p = dae(f"fe{seed}", arrays={"A": n, "B": n, "ix": m, "w": m})
    n_loops = rng.integers(1, 3)
    for li in range(n_loops):
        with p.range_loop(f"i{li}", p.const(m, "M")):
            x = p.load(f"x{li}", "ix", f"i{li}")
            a = p.load(f"a{li}", "A", x)
            v = p.load(f"w{li}", "w", f"i{li}")
            acc = a
            for k in range(rng.integers(1, 4)):
                op = ("+", "*", "^", "max")[rng.integers(0, 4)]
                acc = p.bin(f"t{li}_{k}", op, acc, v)
            pred = p.bin(f"p{li}", (">", "!=", "<")[rng.integers(0, 3)],
                         a, p.const(int(rng.integers(1, 40))))
            c = p.cond(pred, then=f"then{li}")
            with c:
                p.update(("A", "B")[int(rng.integers(0, 2))], x, acc)
            if rng.random() < 0.5:
                with c.orelse(f"else{li}"):
                    p.store("B", x, v)
    mem = {"A": rng.integers(0, 50, n).astype(np.int64),
           "B": rng.integers(0, 50, n).astype(np.int64),
           "ix": rng.integers(0, n, m).astype(np.int64),
           "w": rng.integers(1, 6, m).astype(np.int64)}
    return p, mem


@pytest.mark.parametrize("leg", ["numpy", "numpy-vector", "jax"])
def test_frontend_randprog_sweep(leg):
    target = "numpy" if leg.startswith("numpy") else "jax"
    kw = {}
    if leg == "numpy-vector":
        kw["cu_mode"] = "vector"
    if target == "jax":
        kw["interpret"] = True
    # keep the jax leg affordable: spec only there, both modes on numpy
    modes = ("spec", "dae") if target == "numpy" else ("spec",)
    ran = 0
    for seed in SEEDS:
        p, mem = _rand_program(seed)
        ref = {k: v.copy() for k, v in mem.items()}
        interp.run(p.build(), ref)
        for mode in modes:
            comp = p.compile({"A", "B"}, mode=mode, cache=False)
            m = {k: v.copy() for k, v in mem.items()}
            r = codegen.run(comp, m, target=target, **kw)
            ran += r.target_used == target
            for k in ref:
                assert np.array_equal(m[k], ref[k]), (seed, mode, leg, k)
    assert ran > 0  # the sweep must exercise the generated path


def test_frontend_randprog_sim_engines():
    """The same programs through the simulator's engine modes."""
    for seed in SEEDS[:6]:
        p, mem = _rand_program(seed)
        ref = {k: v.copy() for k, v in mem.items()}
        interp.run(p.build(), ref)
        comp = p.compile({"A", "B"}, cache=False)
        for windowed, pipelined in ((False, False), (True, False),
                                    (False, True), (True, True)):
            m = {k: v.copy() for k, v in mem.items()}
            cfg = machine.MachineConfig(batch_window=windowed,
                                        pipeline_window=pipelined)
            machine.run_dae(comp.agu, comp.cu, m, {"A", "B"}, None, cfg)
            for k in ref:
                assert np.array_equal(m[k], ref[k]), \
                    (seed, windowed, pipelined, k)


# ---------------------------------------------------------------------------
# the two frontend-opened workload families, differentially
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["pagerank", "join"])
def test_new_families_differential(name):
    """pagerank/join (authored *only* in the frontend) hold bit-identical
    to interp across the sim variants and every codegen leg.  The full
    engine-mode × variant matrix runs in test_sim_equivalence/test_codegen
    (both families are in ``ALL``); this is the frontend-local gate."""
    case = ALL[name]()
    runs = pipeline.run_all(case.fn, case.decoupled, case.memory,
                            params=case.params)
    ref = runs["ref"].memory
    for v in ("sta", "dae", "spec"):
        for k in ref:
            assert np.array_equal(runs[v].memory[k], ref[k]), (v, k)
    assert runs["spec"].cycles < runs["dae"].cycles
    comp = runs["spec"].compiled
    for tgt, kw in (("numpy", {}), ("numpy", {"cu_mode": "vector"}),
                    ("jax", {"interpret": True})):
        mem = {k: v.copy() for k, v in case.memory.items()}
        r = codegen.run(comp, mem, case.params, target=tgt, **kw)
        assert r.target_used == tgt, (tgt, r.fallback_reason)
        if kw.get("cu_mode") == "vector":
            assert r.cu_mode == "vector", r.vector_reason
        for k in ref:
            assert np.array_equal(mem[k], ref[k]), (tgt, kw, k)


def test_new_families_are_frontend_authored():
    """The bench builders themselves go through repro.frontend — the
    kernels exist in no hand-rolled form anywhere in the tree."""
    import inspect

    from repro.bench_irregular import join as join_mod
    from repro.bench_irregular import pagerank as pr_mod
    for mod in (pr_mod, join_mod):
        src = inspect.getsource(mod)
        assert "frontend import dae" in src
        assert "f.block(" not in src and "core.ir import" not in src


# ---------------------------------------------------------------------------
# verify=True: verdicts ride the cache payload (PR 10, docs/verify.md)
# ---------------------------------------------------------------------------


def test_compile_verify_clean_cold_and_uncached():
    dec = {"HT", "G"}
    comp = _join_prog().compile(dec, cache=False, verify=True)
    assert comp is not None
    # and the source-level pass on the lowered nest
    _join_prog().build(verify=True)


def test_cache_warm_hit_replays_verdict_without_reverifying(
        tmp_path, monkeypatch):
    import repro.verify as verify_mod

    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    c1 = _join_prog().compile(dec, cache=cc, verify=True)
    assert c1.cache_stats["outcome"] == "cold"
    assert c1._verify_verdict["registry"] == verify_mod.REGISTRY_VERSION

    def boom(*a, **k):
        raise AssertionError("verifier re-ran on a warm hit")

    monkeypatch.setattr(verify_mod, "verify_compiled", boom)
    c2 = _join_prog().compile(dec, cache=cc, verify=True)
    assert c2.cache_stats["outcome"] == "warm"
    assert c2._verify_verdict["diags"] == c1._verify_verdict["diags"]


def test_cache_stale_verdict_registry_recompiles(tmp_path):
    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc, verify=True)
    [name] = [n for n in os.listdir(tmp_path) if n.endswith(".pkl")]
    path = os.path.join(str(tmp_path), name)
    with open(path, "rb") as fh:
        payload = pickle.load(fh)
    payload["verdict"]["registry"] = 0  # verdict minted by an old registry
    with open(path, "wb") as fh:
        pickle.dump(payload, fh)
    c = _join_prog().compile(dec, cache=cc, verify=True)
    assert c.cache_stats["outcome"] == "stale"
    evs = [e for e in cc.events if e.site == "frontend.cache_stale"]
    assert evs and "registry" in evs[-1].cause
    # without verify, the same drifted verdict is irrelevant: warm hit
    with open(path, "rb") as fh:
        payload = pickle.load(fh)
    payload["verdict"]["registry"] = 0
    with open(path, "wb") as fh:
        pickle.dump(payload, fh)
    assert _join_prog().compile(dec, cache=cc).cache_stats["outcome"] \
        == "warm"


def test_compile_verify_raises_on_dirty_verdict(tmp_path):
    import repro.verify as verify_mod

    cc = CompileCache(str(tmp_path))
    dec = {"HT", "G"}
    _join_prog().compile(dec, cache=cc, verify=True)
    [name] = [n for n in os.listdir(tmp_path) if n.endswith(".pkl")]
    path = os.path.join(str(tmp_path), name)
    with open(path, "rb") as fh:
        payload = pickle.load(fh)
    payload["verdict"]["diags"] = [
        ("P02-request-unresolved", "cu:latch", "planted for the test")]
    with open(path, "wb") as fh:
        pickle.dump(payload, fh)
    with pytest.raises(verify_mod.VerifyError, match="P02"):
        _join_prog().compile(dec, cache=cc, verify=True)
