"""Pallas kernels (interpret mode) vs pure-jnp oracles — shape/dtype sweeps
plus property tests on the poison semantics (hypothesis when available,
a seeded-random fallback loop otherwise)."""
import random

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from conftest import dae_test_seed

# fallback sample drawn from the single DAE_TEST_SEED knob (see conftest)
_FALLBACK_SEEDS = sorted(
    random.Random(dae_test_seed()).sample(range(10_000), 15))

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ragged_matmul import ragged_matmul
from repro.kernels.spec_gather import spec_gather
from repro.kernels.spec_scatter import spec_scatter_add

RNG = np.random.default_rng(7)


def _arr(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(dtype))


# ---------------------------------------------------------------------------
# spec_gather
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("v,d,n,bd", [(32, 128, 16, 64), (8, 256, 40, 256),
                                      (64, 512, 7, 128), (4, 128, 1, 128)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_spec_gather_sweep(v, d, n, bd, dtype):
    table = _arr((v, d)).astype(dtype)
    idx = jnp.asarray(RNG.integers(-3, v, n).astype(np.int32))
    got = spec_gather(table, idx, block_d=bd)
    np.testing.assert_allclose(got, ref.spec_gather(table, idx), atol=1e-6)


def test_spec_gather_all_poisoned():
    table = _arr((8, 128))
    idx = jnp.full((5,), -1, jnp.int32)
    assert np.all(np.asarray(spec_gather(table, idx)) == 0)


# ---------------------------------------------------------------------------
# spec_scatter_add
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("v,d,n", [(16, 128, 24), (8, 64, 40), (5, 128, 17)])
def test_spec_scatter_sweep(v, d, n):
    table = _arr((v, d))
    idx = jnp.asarray(RNG.integers(-3, v, n).astype(np.int32))
    vals = _arr((n, d))
    got = spec_scatter_add(table, idx, vals, block_d=64)
    np.testing.assert_allclose(got, ref.spec_scatter_add(table, idx, vals),
                               atol=1e-4)


def _check_scatter_poison_never_commits(seed):
    """Paper §3.1: mis-speculated stores are never committed — rows only
    referenced by poisoned requests are bit-identical afterwards."""
    r = np.random.default_rng(seed)
    v, d, n = 12, 64, 20
    table = jnp.asarray(r.normal(size=(v, d)).astype(np.float32))
    idx = r.integers(0, v, n).astype(np.int32)
    poisoned_rows = r.choice(v, 4, replace=False)
    idx = np.where(np.isin(idx, poisoned_rows), -1, idx)
    out = spec_scatter_add(table, jnp.asarray(idx),
                           jnp.asarray(r.normal(size=(n, d)).astype(np.float32)),
                           block_d=64)
    touched = set(int(i) for i in idx if i >= 0)
    for row in range(v):
        if row not in touched:
            np.testing.assert_array_equal(np.asarray(out[row]),
                                          np.asarray(table[row]))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None, derandomize=True)
    @given(st.integers(0, 10_000))
    def test_spec_scatter_poison_never_commits(seed):
        _check_scatter_poison_never_commits(seed)
else:
    @pytest.mark.parametrize("seed", _FALLBACK_SEEDS)
    def test_spec_scatter_poison_never_commits(seed):
        _check_scatter_poison_never_commits(seed)


# ---------------------------------------------------------------------------
# ragged_matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("e,c,d,f,bm,bn,bk", [
    (4, 64, 128, 256, 32, 128, 64),
    (2, 128, 256, 128, 128, 128, 128),
    (8, 32, 64, 64, 32, 64, 64),
])
def test_ragged_matmul_sweep(e, c, d, f, bm, bn, bk):
    x = _arr((e * c, d))
    w = _arr((e, d, f))
    got = ragged_matmul(x, w, capacity=c, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.ragged_matmul(x, w, c),
                               atol=1e-3, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,t,d,bq,bk", [(2, 3, 256, 64, 64, 64),
                                           (1, 2, 128, 128, 128, 64),
                                           (1, 1, 512, 64, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, t, d, bq, bk, causal):
    q, k, v = _arr((b, h, t, d)), _arr((b, h, t, d)), _arr((b, h, t, d))
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    want = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


# ---------------------------------------------------------------------------
# paged_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,h,d,p,page,nmax", [(3, 4, 64, 16, 8, 5),
                                               (1, 8, 128, 8, 16, 3),
                                               (2, 2, 64, 32, 8, 8)])
def test_paged_attention_sweep(b, h, d, p, page, nmax):
    q = _arr((b, h, d))
    kp, vp = _arr((p, page, h, d)), _arr((p, page, h, d))
    pt = jnp.asarray(RNG.integers(0, p, (b, nmax)).astype(np.int32))
    seq = jnp.asarray(RNG.integers(1, page * nmax, b).astype(np.int32))
    # poison pages past each sequence's end (speculative tail fetch)
    used = (np.asarray(seq) + page - 1) // page
    ptn = np.asarray(pt).copy()
    for i in range(b):
        ptn[i, used[i]:] = -1
    pt = jnp.asarray(ptn)
    got = paged_attention(q, kp, vp, pt, seq)
    want = ref.paged_attention(q, kp, vp, pt, seq)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)


def test_paged_matches_flash_decode():
    """Paged decode == dense attention over the materialized cache."""
    b, h, d, page = 2, 4, 64, 8
    t = 40
    n_pages = t // page + 1
    q1 = _arr((b, h, 1, d))
    k = _arr((b, h, t, d))
    v = _arr((b, h, t, d))
    want = ref.flash_attention(q1, k, v, causal=False)[:, :, 0]

    # scatter the dense cache into pages
    pool_k = np.zeros((b * n_pages, page, h, d), np.float32)
    pool_v = np.zeros_like(pool_k)
    pt = np.full((b, n_pages), -1, np.int32)
    for i in range(b):
        for pg in range((t + page - 1) // page):
            pid = i * n_pages + pg
            lo, hi = pg * page, min((pg + 1) * page, t)
            pool_k[pid, :hi - lo] = np.asarray(k[i, :, lo:hi]).transpose(1, 0, 2)
            pool_v[pid, :hi - lo] = np.asarray(v[i, :, lo:hi]).transpose(1, 0, 2)
            pt[i, pg] = pid
    got = paged_attention(q1[:, :, 0], jnp.asarray(pool_k),
                          jnp.asarray(pool_v), jnp.asarray(pt),
                          jnp.full((b,), t, jnp.int32))
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=2e-3)
